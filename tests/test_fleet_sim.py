"""Virtual-clock fleet simulation: seeded-trace determinism, bit-exact
run reproducibility, plan-aware tier placement, and the graceful-drain
regression (zero lost, zero late-served re-routed requests).  The
reduced-scale SLO acceptance run (the CI ``fleet`` job's workload) is
marked ``fleet`` and excluded from tier-1."""

import json
import os
import sys

import numpy as np
import pytest

from repro.fleet import (DEFAULT_TIERS, SimWorkerSpec, make_trace,
                         profile_speed, simulate)
from repro.fleet.sim import V5E_IMAGE_S, V5E_OVERHEAD_S

SPECS = (SimWorkerSpec("w0-edge", "edge"),
         SimWorkerSpec("w1-v5e", "v5e"),
         SimWorkerSpec("w2-v5p", "v5p"))


def _rate(occupancy=2.2, max_batch=8):
    """Offered load as a multiple of one v5e's full-batch capacity."""
    return occupancy * max_batch / (V5E_OVERHEAD_S
                                    + max_batch * V5E_IMAGE_S)


def test_trace_is_seed_deterministic():
    a = make_trace(2000, _rate(), seed=7)
    b = make_trace(2000, _rate(), seed=7)
    np.testing.assert_array_equal(a.arrivals, b.arrivals)
    np.testing.assert_array_equal(a.tier_idx, b.tier_idx)
    np.testing.assert_array_equal(a.deadlines, b.deadlines)
    c = make_trace(2000, _rate(), seed=8)
    assert not np.array_equal(a.arrivals, c.arrivals)
    # tier shares land near their spec at this n
    for t, (name, spec) in enumerate(DEFAULT_TIERS.items()):
        frac = float(np.mean(a.tier_idx == t))
        assert abs(frac - spec.share) < 0.05, (name, frac)


def test_trace_validation():
    with pytest.raises(ValueError):
        make_trace(0, _rate())
    with pytest.raises(ValueError):
        make_trace(10, 0.0)


def test_profile_speeds_are_catalog_ratios():
    edge, v5e, v5p = (s.resolve_profile() for s in SPECS)
    assert profile_speed(v5e) == pytest.approx(1.0)
    assert profile_speed(edge) == pytest.approx(0.1)
    assert profile_speed(v5p) > 2.0


def test_sim_is_bit_reproducible():
    """Same trace, same router → byte-identical result payloads (what
    lets BENCH_fleet.json be committed and diffed)."""
    trace = make_trace(5000, _rate(), seed=42)
    a = simulate(SPECS, trace, "plan_aware")
    b = simulate(SPECS, trace, "plan_aware")
    assert json.dumps(a.to_payload()) == json.dumps(b.to_payload())


def test_sim_completes_everything_under_every_router():
    trace = make_trace(5000, _rate(), seed=42)
    for router in ("round_robin", "least_loaded", "plan_aware"):
        r = simulate(SPECS, trace, router)
        assert r.lost == 0 and r.completed == len(trace), router
        assert sum(w["served"] for w in r.per_worker.values()) \
            == len(trace)


def test_sim_validation():
    trace = make_trace(10, _rate())
    with pytest.raises(ValueError, match="duplicate"):
        simulate((SPECS[0], SPECS[0]), trace)
    with pytest.raises(ValueError, match="go together"):
        simulate(SPECS, trace, drain_at=1.0)


def test_plan_aware_places_tiers_on_matching_profiles():
    """The router's economics show up in placement: essentially all
    interactive traffic lands on the fast tiers, and the edge part
    earns its keep on undeadlined work."""
    trace = make_trace(20_000, _rate(), seed=42)
    r = simulate(SPECS, trace, "plan_aware")
    assert r.all_slos_met and r.late == 0
    edge = r.per_worker["w0-edge"]["served_by_tier"]
    interactive_total = r.per_tier["interactive"]["served"]
    assert edge.get("interactive", 0) <= 0.01 * interactive_total
    assert r.per_worker["w0-edge"]["served"] > 0
    # and the fast tier carries the deadline traffic
    fast = r.per_worker["w2-v5p"]["served_by_tier"]
    assert fast.get("interactive", 0) >= 0.5 * interactive_total


def test_drain_regression_zero_lost_zero_late():
    """The graceful-drain invariant the fleet benchmark pins, as a
    regression test: draining the v5e mid-trace re-routes its queue and
    loses nothing — every request completes, and no re-routed request
    with a deadline is served past it."""
    trace = make_trace(20_000, _rate(), seed=42)
    r = simulate(SPECS, trace, "plan_aware",
                 drain_at=0.4 * float(trace.arrivals[-1]),
                 drain_worker="w1-v5e")
    assert r.completed == len(trace) and r.lost == 0
    assert r.rerouted > 0                    # the drain had a queue
    assert r.late_rerouted == 0              # nothing served late by it
    assert r.per_worker["w1-v5e"]["drained"]
    assert r.all_slos_met                    # fleet absorbs the drain


def test_drain_after_trace_end_is_a_noop_drain():
    trace = make_trace(500, _rate(), seed=1)
    r = simulate(SPECS, trace, "plan_aware",
                 drain_at=1e9, drain_worker="w1-v5e")
    assert r.completed == len(trace) and r.rerouted == 0
    assert r.per_worker["w1-v5e"]["drained"]


def test_kill_respawn_regression_zero_lost():
    """The crash-recovery invariant the recovery benchmark pins: a kill
    voids the worker's in-flight batch (the process died mid-dispatch,
    unlike a drain), re-routes it and the queue on original deadlines,
    and a warm respawn brings the worker back — nothing is lost."""
    trace = make_trace(20_000, _rate(), seed=42)
    horizon = float(trace.arrivals[-1])
    r = simulate(SPECS, trace, "plan_aware",
                 kill_at=0.4 * horizon, kill_worker="w2-v5p",
                 respawn_at=0.6 * horizon)
    assert r.completed == len(trace) and r.lost == 0
    assert r.kill_rerouted > 0               # queue/in-flight re-routed
    assert r.rerouted >= r.kill_rerouted
    assert r.killed_worker == "w2-v5p"
    assert r.respawn_at_s == pytest.approx(0.6 * horizon)
    w = r.per_worker["w2-v5p"]
    assert w["killed"] and w["respawned"] and not w["drained"]
    # without the respawn the survivors still lose nothing, but the
    # dead worker serves strictly less — i.e. the respawn demonstrably
    # returned it to rotation
    r_dead = simulate(SPECS, trace, "plan_aware",
                      kill_at=0.4 * horizon, kill_worker="w2-v5p")
    assert r_dead.completed == len(trace) and r_dead.lost == 0
    assert r_dead.per_worker["w2-v5p"]["killed"]
    assert not r_dead.per_worker["w2-v5p"]["respawned"]
    assert w["served"] > r_dead.per_worker["w2-v5p"]["served"]


def test_kill_is_bit_reproducible_and_additive():
    """Same trace + same kill schedule → byte-identical payloads; and a
    run with no kill reports the additive fields as inert defaults (the
    committed BENCH_fleet contract)."""
    trace = make_trace(5000, _rate(), seed=42)
    horizon = float(trace.arrivals[-1])
    kw = dict(kill_at=0.4 * horizon, kill_worker="w1-v5e",
              respawn_at=0.5 * horizon)
    a = simulate(SPECS, trace, "plan_aware", **kw)
    b = simulate(SPECS, trace, "plan_aware", **kw)
    assert json.dumps(a.to_payload()) == json.dumps(b.to_payload())
    plain = simulate(SPECS, trace, "plan_aware")
    assert plain.kill_rerouted == 0
    assert plain.killed_worker is None and plain.respawn_at_s is None
    assert not any(w["killed"] or w["respawned"]
                   for w in plain.per_worker.values())


def test_kill_validation():
    trace = make_trace(10, _rate())
    with pytest.raises(ValueError, match="go together"):
        simulate(SPECS, trace, kill_at=1.0)
    with pytest.raises(ValueError, match="requires"):
        simulate(SPECS, trace, respawn_at=1.0)
    with pytest.raises(ValueError, match="kill_at"):
        simulate(SPECS, trace, kill_at=2.0, kill_worker="w1-v5e",
                 respawn_at=1.0)
    with pytest.raises(ValueError, match="unknown kill_worker"):
        simulate(SPECS, trace, kill_at=1.0, kill_worker="nope")


def test_kill_after_trace_end_reroutes_nothing():
    trace = make_trace(500, _rate(), seed=1)
    r = simulate(SPECS, trace, "plan_aware",
                 kill_at=1e9, kill_worker="w1-v5e")
    assert r.completed == len(trace) and r.kill_rerouted == 0
    assert r.per_worker["w1-v5e"]["killed"]


def test_mixed_plan_trace_respects_workload_hosting():
    """A 70/30 CNN/MoE traffic mix over a fleet where only the fast
    tiers host the MoE plan (it is infeasible on edge — see
    ``plan_moe_deployment``): everything completes, and the edge worker
    never serves a single MoE request."""
    mixed = (SimWorkerSpec("w0-edge", "edge", plan_ids=("cnn",)),
             SimWorkerSpec("w1-v5e", "v5e", plan_ids=("cnn", "moe")),
             SimWorkerSpec("w2-v5p", "v5p", plan_ids=("cnn", "moe")))
    trace = make_trace(10_000, _rate(), seed=42,
                       plan_mix={"cnn": 0.7, "moe": 0.3})
    assert trace.plan_ids == ("cnn", "moe")
    n_moe = int(np.sum(trace.plan_idx == 1))
    assert abs(n_moe / len(trace) - 0.3) < 0.05
    r = simulate(mixed, trace, "plan_aware")
    assert r.completed == len(trace) and r.lost == 0
    edge = r.per_worker["w0-edge"]
    assert edge["served_by_plan"].get("moe", 0) == 0
    assert edge["served"] > 0                 # edge still earns its keep
    moe_served = sum(w["served_by_plan"].get("moe", 0)
                     for w in r.per_worker.values())
    assert moe_served == n_moe
    # batches never mix plans, so per-plan counts are exact per worker
    for w in r.per_worker.values():
        assert sum(w["served_by_plan"].values()) == w["served"]


def test_mixed_plan_trace_rng_is_backwards_compatible():
    """Adding ``plan_mix`` must not perturb the single-plan rng stream:
    the committed BENCH_fleet payload depends on these draws being
    bit-identical to what PR 6 recorded."""
    base = make_trace(2000, _rate(), seed=7)
    mixed = make_trace(2000, _rate(), seed=7,
                       plan_mix={"cnn": 0.5, "moe": 0.5})
    np.testing.assert_array_equal(base.arrivals, mixed.arrivals)
    np.testing.assert_array_equal(base.tier_idx, mixed.tier_idx)
    np.testing.assert_array_equal(base.deadlines, mixed.deadlines)
    assert base.plan_idx is None and mixed.plan_idx is not None
    with pytest.raises(ValueError, match="sum to 1"):
        make_trace(10, _rate(), plan_mix={"cnn": 0.7, "moe": 0.2})


# ---------------------------------------------------------------------------
# reduced-scale SLO acceptance — the CI `fleet` job (-m fleet)
# ---------------------------------------------------------------------------

@pytest.mark.fleet
def test_fleet_bench_reduced_scale_acceptance(tmp_path):
    """The benchmark's own acceptance gates at CI scale (50k requests):
    plan-aware meets every SLO the single v5e misses, beats round-robin
    on deadline-tier p99, and the mid-trace drain loses nothing."""
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks import fleet_bench

    payload = fleet_bench.run(tmp_path / "BENCH_fleet.json",
                              requests=50_000)
    acc = payload["acceptance"]
    assert payload["accepted"]
    assert acc["single_v5e_missed_tiers"]          # overload is real
    assert acc["plan_aware_meets_single_missed"]
    assert acc["plan_aware_all_slos_met"]
    assert acc["plan_aware_beats_round_robin_deadline_p99"]
    assert acc["drain_rerouted"] > 0
    assert acc["drain_zero_lost"] and acc["drain_zero_late_rerouted"]
    # the recorded artifact exists and round-trips
    again = json.loads((tmp_path / "BENCH_fleet.json").read_text())
    assert again["accepted"] and again["requests"] == 50_000
