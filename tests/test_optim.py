"""AdamW (+8-bit states), schedules, gradient compression codec."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.optim.adamw import dequantize_state, quantize_state
from repro.optim.schedule import cosine_schedule


def _optimize_quadratic(state_dtype, steps=60):
    cfg = AdamWConfig(weight_decay=0.0, state_dtype=state_dtype)
    target = jnp.asarray(np.random.default_rng(0).normal(size=(4, 8)),
                         jnp.float32)
    params = {"w": jnp.zeros((4, 8), jnp.float32)}
    opt = adamw_init(params, cfg)
    for _ in range(steps):
        grads = {"w": 2 * (params["w"] - target)}
        params, opt, _ = adamw_update(grads, opt, params, 0.05, cfg)
    return float(jnp.mean((params["w"] - target) ** 2))


def test_adamw_converges_fp32():
    assert _optimize_quadratic("float32") < 1e-2


def test_adamw_converges_int8():
    assert _optimize_quadratic("int8") < 5e-2


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       scale=st.floats(1e-4, 1e3))
def test_int8_codec_roundtrip(seed, scale):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(300,)) * scale, jnp.float32)
    q = quantize_state(x)
    back = dequantize_state(q, x.shape)
    # block-wise 8-bit: error bounded by blockmax/127
    err = np.abs(np.asarray(back - x))
    bound = np.asarray(jnp.max(jnp.abs(x))) / 127 + 1e-9
    assert err.max() <= bound * 1.01


def test_grad_clip_applied():
    cfg = AdamWConfig(grad_clip=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros((8,), jnp.float32)}
    opt = adamw_init(params, cfg)
    huge = {"w": jnp.full((8,), 1e6, jnp.float32)}
    p1, _, m = adamw_update(huge, opt, params, 0.1, cfg)
    assert float(m["grad_norm"]) > 1e5
    assert bool(jnp.all(jnp.isfinite(p1["w"])))
    assert float(jnp.max(jnp.abs(p1["w"]))) < 1.0   # step bounded by lr scale


def test_cosine_schedule_shape():
    lr0 = cosine_schedule(0, peak_lr=1.0, warmup_steps=10, total_steps=100)
    lr10 = cosine_schedule(10, peak_lr=1.0, warmup_steps=10,
                           total_steps=100)
    lr100 = cosine_schedule(100, peak_lr=1.0, warmup_steps=10,
                            total_steps=100)
    assert float(lr0) == 0.0
    assert float(lr10) == pytest.approx(1.0)
    assert float(lr100) == pytest.approx(0.1, abs=1e-6)


def test_grad_compression_roundtrip():
    from repro.parallel.compress import (compress_grads_int8,
                                         decompress_grads)
    g = {"a": jnp.asarray(np.random.default_rng(1).normal(size=(64, 3)),
                          jnp.float32)}
    q = compress_grads_int8(g)
    back = decompress_grads(q, g)
    err = float(jnp.max(jnp.abs(back["a"] - g["a"])))
    assert err <= float(jnp.max(jnp.abs(g["a"]))) / 127 * 1.01
