"""Mamba-2 SSD: chunked algorithm vs naive recurrence oracle; decode step;
conv cache continuity."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.ssm import (_ssd_chunked, _ssd_decode, causal_conv1d)


def naive_ssd(x, dt, a, B, C):
    """Direct recurrence: h_t = exp(dt_t a) h_{t-1} + dt_t B_t x_t."""
    b, s, nh, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = nh // g
    Bh = jnp.repeat(B, rep, axis=2).astype(jnp.float32)
    Ch = jnp.repeat(C, rep, axis=2).astype(jnp.float32)
    h = jnp.zeros((b, nh, n, p), jnp.float32)
    ys = []
    for t in range(s):
        da = jnp.exp(dt[:, t] * a[None, :])               # (b,nh)
        xdt = (x[:, t] * dt[:, t][..., None]).astype(jnp.float32)
        h = h * da[:, :, None, None] + \
            jnp.einsum("bhn,bhp->bhnp", Bh[:, t], xdt)
        ys.append(jnp.einsum("bhn,bhnp->bhp", Ch[:, t], h))
    return jnp.stack(ys, axis=1), h


def _rand(seed, b=2, s=24, nh=4, p=8, g=1, n=16):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(b, s, nh, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(b, s, nh)), jnp.float32)
    a = -jnp.asarray(rng.uniform(0.5, 4.0, size=(nh,)), jnp.float32)
    B = jnp.asarray(rng.normal(size=(b, s, g, n)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(b, s, g, n)), jnp.float32)
    return x, dt, a, B, C


def test_chunked_matches_recurrence():
    x, dt, a, B, C = _rand(0)
    y, h = _ssd_chunked(x, dt, a, B, C, chunk=8)
    yr, hr = naive_ssd(x, dt, a, B, C)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr),
                               rtol=1e-4, atol=1e-4)


def test_chunked_non_divisible_seq():
    x, dt, a, B, C = _rand(1, s=19)
    y, h = _ssd_chunked(x, dt, a, B, C, chunk=8)
    yr, hr = naive_ssd(x, dt, a, B, C)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr),
                               rtol=1e-4, atol=1e-4)


def test_group_broadcast():
    """n_groups < n_heads: group B/C broadcast across heads."""
    x, dt, a, B, C = _rand(2, nh=6, g=2)
    y, h = _ssd_chunked(x, dt, a, B, C, chunk=8)
    yr, hr = naive_ssd(x, dt, a, B, C)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-4, atol=1e-4)


def test_decode_continues_chunked():
    """Prefill s tokens chunked, then decode token s+1 — must equal the
    full chunked pass over s+1 tokens."""
    x, dt, a, B, C = _rand(3, s=17)
    y_full, h_full = _ssd_chunked(x, dt, a, B, C, chunk=8)
    y_pre, h_pre = _ssd_chunked(x[:, :16], dt[:, :16], a, B[:, :16],
                                C[:, :16], chunk=8)
    y_dec, h_dec = _ssd_decode(x[:, 16:17], dt[:, 16:17], a, B[:, 16:17],
                               C[:, 16:17], h_pre)
    np.testing.assert_allclose(np.asarray(y_dec[:, 0]),
                               np.asarray(y_full[:, 16]),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_dec), np.asarray(h_full),
                               rtol=1e-4, atol=1e-4)


def test_conv_cache_continuity():
    """Streaming conv1d over a split sequence == one-shot conv1d."""
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=(2, 20, 8)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(4, 8)), jnp.float32)
    y_full, _ = causal_conv1d(x, w)
    y1, st = causal_conv1d(x[:, :13], w)
    y2, _ = causal_conv1d(x[:, 13:], w, st)
    y_split = jnp.concatenate([y1, y2], axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_split),
                               rtol=1e-5, atol=1e-5)
