"""Sharding rules: divisibility handling, fsdp wrap, opt-state specs, and a
real multi-device sharded train step (subprocess with 8 host devices)."""

import subprocess
import sys
import textwrap

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.models import build_model
from repro.parallel.sharding import ShardingRules, choose_mode


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def _spec_of(tree_spec, *path):
    node = tree_spec
    for p in path:
        node = node[p]
    return node


def test_granite_mqa_head_not_sharded():
    """kv=1 head cannot shard over model=16 → replicated; q heads (48)
    don't divide 16 either... 48 % 16 == 0 so they do."""
    cfg = get_config("granite-20b")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # emulate the production axis sizes through a fake mesh of size 1 but
    # checking the rule logic directly with tp_size patched
    rules = ShardingRules(cfg, mesh, mode="tp")
    rules.tp_size = 16
    model = build_model(cfg)
    shapes = model.init_abstract()
    spec = rules.params_spec(shapes)
    wq = _spec_of(spec, "stack", "s0", "attn", "wq")
    wk = _spec_of(spec, "stack", "s0", "attn", "wk")
    assert wq == P(None, None, "model", None)     # 48 heads ÷ 16 OK
    assert wk == P(None, None, None, None)        # 1 kv head: replicated


def test_gemma2_2b_heads_replicated():
    cfg = get_config("gemma2-2b")                  # 8 q heads < 16
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = ShardingRules(cfg, mesh, mode="tp")
    rules.tp_size = 16
    spec = rules.params_spec(build_model(cfg).init_abstract())
    assert _spec_of(spec, "stack", "s0", "attn", "wq") == \
        P(None, None, None, None)
    # but MLP hidden dim shards fine
    assert _spec_of(spec, "stack", "s0", "mlp", "w_up") == \
        P(None, None, "model")


def test_moe_expert_parallel_spec():
    cfg = get_config("qwen3-moe-30b-a3b")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = ShardingRules(cfg, mesh, mode="tp")
    rules.tp_size = 16
    spec = rules.params_spec(build_model(cfg).init_abstract())
    assert _spec_of(spec, "stack", "s0", "moe", "w_up") == \
        P(None, "model", None, None)               # experts over model


def test_fsdp_adds_data_axis():
    cfg = get_config("llama4-maverick-400b-a17b")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = ShardingRules(cfg, mesh, mode="fsdp")
    rules.tp_size = 16
    rules.dp_size = 16
    spec = rules.params_spec(build_model(cfg).init_abstract())
    wq = _spec_of(spec, "stack", "s0", "attn", "wq")
    assert "data" in jax.tree.leaves(wq) or "data" in str(wq)


def test_choose_mode_policy():
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    class FakeShape(dict):
        pass
    small = get_config("llama3.2-3b")
    big = get_config("jamba-1.5-large-398b")
    # patch mesh.shape lookup via real small mesh: tp size 1 → everything
    # is "big"; use the production ratio directly instead
    assert choose_mode(big, mesh) == "fsdp"


def test_multidevice_sharded_step_runs():
    """8 host devices, (4,2) mesh: a sharded train step must produce the
    same loss as the single-device run (SPMD correctness end-to-end)."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, "src")
        import jax, jax.numpy as jnp
        from repro.configs import smoke_config
        from repro.models import build_model
        from repro.optim import AdamWConfig, adamw_init
        from repro.parallel.sharding import ShardingRules
        from repro.train.step import make_train_step
        from repro.data import DataConfig
        from repro.data.pipeline import batch_at

        cfg = smoke_config("qwen3-moe-30b-a3b").with_overrides(
            dtype="float32")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        opt_cfg = AdamWConfig()
        opt = adamw_init(params, opt_cfg)
        dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                          global_batch=8)
        batch = batch_at(dcfg, 0)
        step = make_train_step(model, opt_cfg)

        # single device reference
        l_ref = jax.jit(step)(params, opt, batch)[2]["loss"]

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        rules = ShardingRules(cfg, mesh, mode="tp")
        psh = rules.to_sharding(rules.params_spec(
            jax.eval_shape(lambda: params)))
        osh = rules.to_sharding(rules.opt_spec(
            jax.eval_shape(lambda: opt),
            rules.params_spec(jax.eval_shape(lambda: params))))
        bsh = rules.to_sharding(rules.batch_spec(
            jax.eval_shape(lambda: batch)))
        with mesh:
            pp = jax.device_put(params, psh)
            oo = jax.device_put(opt, osh)
            bb = jax.device_put(batch, bsh)
            l_sh = jax.jit(step, in_shardings=(psh, osh, bsh),
                           out_shardings=(psh, osh, None))(
                pp, oo, bb)[2]["loss"]
        err = abs(float(l_ref) - float(l_sh))
        assert err < 1e-3, (float(l_ref), float(l_sh))
        print("SHARDED_OK", float(l_ref), float(l_sh))
    """)
    out = subprocess.run([sys.executable, "-c", prog], cwd=".",
                         capture_output=True, text=True, timeout=600)
    assert "SHARDED_OK" in out.stdout, out.stdout + out.stderr
