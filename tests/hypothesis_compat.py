"""Guarded hypothesis import (ISSUE satellite: the seed suite died at
collection on ``ModuleNotFoundError: hypothesis``).

``from hypothesis_compat import given, settings, st`` works with or
without hypothesis installed: when it is missing, ``@given`` replaces the
test with a cleanly-skipped stand-in (via ``pytest.mark.skip``) so the
module's deterministic tests still collect and run — strictly more
coverage than skipping the whole module with ``pytest.importorskip``.
CI installs hypothesis from requirements-dev.txt, so property tests
always run there.
"""

from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            @pytest.mark.skip(reason="hypothesis not installed "
                              "(see requirements-dev.txt)")
            def _skipped():
                pass
            _skipped.__name__ = fn.__name__
            _skipped.__doc__ = fn.__doc__
            return _skipped
        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _StrategyStub:
        """Evaluates module-level strategy expressions to inert Nones."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()
