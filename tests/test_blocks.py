"""The repro.blocks ConvBlock API: registry, metadata, batched forward."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.blocks import (BIT_RANGE, ConvBlock, Conv2Block, get_block,
                          list_blocks, register_block, unregister_block)
from repro.core.cnn import (CNNConfig, ConvLayerSpec, choose_blocks,
                            cnn_forward, cnn_forward_ref, init_cnn)
from repro.kernels import ops, ref

DESIGN_POINTS = [(4, 4), (8, 8), (8, 10)]


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_round_trip():
    names = list_blocks()
    assert set(names) >= {"conv1", "conv2", "conv3", "conv4"}
    for name in names:
        blk = get_block(name)
        assert blk.name == name
        assert get_block(blk) is blk          # ConvBlock coerces to itself


def test_registry_unknown_and_duplicate():
    with pytest.raises(KeyError, match="conv99"):
        get_block("conv99")
    with pytest.raises(ValueError, match="already registered"):
        register_block(get_block("conv1"))


def test_register_custom_block():
    custom = Conv2Block(name="conv2_custom", convs_per_step=1,
                        dual_output=False, description="test clone")
    register_block(custom)
    try:
        assert "conv2_custom" in list_blocks()
        rng = np.random.default_rng(3)
        x = ops.quantize_fixed(
            jnp.asarray(rng.integers(-100, 100, (16, 128)), jnp.float32), 8)
        w = ops.quantize_fixed(
            jnp.asarray(rng.integers(-100, 100, (3, 3)), jnp.float32), 8)
        y = get_block("conv2_custom").apply(x, w, data_bits=8, coeff_bits=8)
        np.testing.assert_array_equal(np.asarray(y),
                                      np.asarray(custom.reference(x, w)))
    finally:
        unregister_block("conv2_custom")
    assert "conv2_custom" not in list_blocks()


# ---------------------------------------------------------------------------
# metadata
# ---------------------------------------------------------------------------

def test_block_metadata():
    for name in ("conv1", "conv2", "conv3", "conv4"):
        blk = get_block(name)
        assert blk.dual_output == (name in ("conv3", "conv4"))
        assert blk.convs_per_step == (2 if blk.dual_output else 1)
        assert blk.weight_shape(8) == ((2, 3, 3) if blk.dual_output
                                       else (3, 3))
        assert blk.supports(8, 8) and not blk.supports(2, 8)
    assert get_block("conv3").packed_ok(4, 4)
    assert not get_block("conv3").packed_ok(8, 8)


def test_apply_validates():
    blk = get_block("conv2")
    x = jnp.zeros((16, 128), jnp.int8)
    with pytest.raises(ValueError, match="unsupported design point"):
        blk.apply(x, jnp.zeros((3, 3), jnp.int8), data_bits=2, coeff_bits=8)
    with pytest.raises(ValueError, match="weight shape"):
        blk.apply(x, jnp.zeros((2, 3, 3), jnp.int8),
                  data_bits=8, coeff_bits=8)
    with pytest.raises(ValueError, match="not divisible"):
        blk.apply(jnp.zeros((17, 128), jnp.int8), jnp.zeros((3, 3), jnp.int8),
                  data_bits=8, coeff_bits=8)


# ---------------------------------------------------------------------------
# apply_batched: bit-exact vs the CNN oracle for every block
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("db,cb", DESIGN_POINTS)
@pytest.mark.parametrize("name", ["conv1", "conv2", "conv3", "conv4"])
def test_apply_batched_bit_exact(name, db, cb):
    """A two-layer CNN forced onto one block (odd + even out_channels to
    exercise the dual-output pairing tail) must equal cnn_forward_ref."""
    cfg = CNNConfig(layers=(
        ConvLayerSpec(2, 3, data_bits=db, coeff_bits=cb, block=name),
        ConvLayerSpec(3, 4, data_bits=db, coeff_bits=cb, block=name),
    ), img_h=16, img_w=128)
    params = init_cnn(jax.random.PRNGKey(42), cfg)
    rng = np.random.default_rng(db * 10 + cb)
    x = ops.quantize_fixed(
        jnp.asarray(rng.integers(0, 1 << (db - 1), (16, 128, 2)),
                    jnp.float32), db)
    blocks = [get_block(name)] * 2
    y = cnn_forward(params, x, cfg, blocks)
    yr = cnn_forward_ref(params, x, cfg)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(yr))


# BIT_RANGE edges, the Conv3 packed/unpacked boundary (d+c = 12 is the
# last packed point, 13 the first unpacked), and the narrow-accumulator
# guard: (3, 3) runs the int16 _acc_dtype path (d + c + 5 ≤ 16)
EDGE_POINTS = [
    (BIT_RANGE[0], BIT_RANGE[0]), (BIT_RANGE[0], BIT_RANGE[1]),
    (BIT_RANGE[1], BIT_RANGE[0]), (BIT_RANGE[1], BIT_RANGE[1]),
    (6, 6), (8, 4), (5, 7),        # data + coeff = 12: packed Conv3
    (7, 6), (8, 5),                # data + coeff = 13: just unpacked
]


@settings(max_examples=10, deadline=None)
@given(name=st.sampled_from(["conv1", "conv2", "conv3", "conv4"]),
       point=st.sampled_from(EDGE_POINTS),
       n=st.integers(min_value=1, max_value=3),
       seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_apply_batched_nhwc_bit_exact_property(name, point, n, seed):
    """Property: (N, H, W, C) batches through every registry block at the
    bit-range edges — including the Conv3 packing boundary and the int16
    accumulator regime — equal the per-image scalar oracle exactly.  Odd
    out_channels exercise the dual-output pairing tail."""
    d, c = point
    blk = get_block(name)
    rng = np.random.default_rng(seed)
    ic, oc, h, w = 2, 3, 16, 64
    x = ops.quantize_fixed(
        jnp.asarray(rng.integers(-(1 << (d - 1)), 1 << (d - 1),
                                 (n, h, w, ic)), jnp.float32), d)
    wts = ops.quantize_fixed(
        jnp.asarray(rng.integers(-(1 << (c - 1)), 1 << (c - 1),
                                 (oc, ic, 3, 3)), jnp.float32), c)
    acc = blk.apply_batched(x, wts, data_bits=d, coeff_bits=c)
    assert acc.dtype == jnp.int32 and acc.shape == (n, oc, h, w)
    accr = jnp.stack([jnp.stack([
        sum(ref.conv2d_3x3_ref(x[i, :, :, j].astype(jnp.int32),
                               wts[o, j].astype(jnp.int32))
            for j in range(ic))
        for o in range(oc)]) for i in range(n)])
    np.testing.assert_array_equal(np.asarray(acc), np.asarray(accr))


def test_apply_batched_raw_accumulator():
    """apply_batched returns the exact int32 Σ_ic accumulator."""
    from repro.kernels import ref
    rng = np.random.default_rng(0)
    x = ops.quantize_fixed(
        jnp.asarray(rng.integers(-100, 100, (16, 128, 3)), jnp.float32), 8)
    w = ops.quantize_fixed(
        jnp.asarray(rng.integers(-100, 100, (5, 3, 3, 3)), jnp.float32), 8)
    for name in list_blocks():
        acc = get_block(name).apply_batched(x, w, data_bits=8, coeff_bits=8)
        accr = jnp.stack([
            sum(ref.conv2d_3x3_ref(x[:, :, ic], w[oc, ic])
                for ic in range(3)) for oc in range(5)])
        assert acc.dtype == jnp.int32
        np.testing.assert_array_equal(np.asarray(acc), np.asarray(accr))


# ---------------------------------------------------------------------------
# choose_blocks honors explicit overrides
# ---------------------------------------------------------------------------

def test_choose_blocks_respects_override():
    cfg = CNNConfig(layers=(
        ConvLayerSpec(1, 4, data_bits=8, coeff_bits=6, block="conv1"),
        ConvLayerSpec(4, 4, data_bits=8, coeff_bits=6),
        ConvLayerSpec(4, 2, data_bits=6, coeff_bits=6, block="conv3"),
    ), img_h=16, img_w=128)
    blocks = choose_blocks(cfg)
    assert blocks[0] is get_block("conv1")
    assert blocks[2] is get_block("conv3")
    assert isinstance(blocks[1], ConvBlock)
