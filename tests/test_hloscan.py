"""HLO analyzer + jaxpr census: trip counts, collective factors, op
classification on known workloads."""

import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hloscan


def test_jaxpr_dot_flops():
    fn = lambda a, b: a @ b
    x = jnp.zeros((64, 32))
    y = jnp.zeros((32, 16))
    res = hloscan.jaxpr_resources(fn, x, y)
    assert res["mxu_flops"] == 2 * 64 * 32 * 16


def test_jaxpr_scan_multiplier():
    def fn(x):
        def body(c, _):
            return c @ c, None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y
    x = jnp.zeros((16, 16))
    res = hloscan.jaxpr_resources(fn, x)
    assert res["mxu_flops"] == 7 * 2 * 16 ** 3


def test_jaxpr_elementwise_census():
    fn = lambda a: jnp.tanh(a) + a
    x = jnp.zeros((128,))
    res = hloscan.jaxpr_resources(fn, x)
    assert res["vpu_count"] >= 256          # tanh + add
    assert res["add_chain"] >= 128


def test_shape_bytes():
    assert hloscan._shape_bytes("bf16[4,8]{1,0}") == 64
    assert hloscan._shape_bytes("f32[10]") == 40
    assert hloscan._shape_bytes("(f32[2], s8[16])") == 24
    assert hloscan._shape_bytes("pred[]") == 1


def test_analyzer_on_scanned_sharded_matmul():
    """End-to-end: 8 host devices, scan(10) of a sharded matmul; the
    analyzer must count 10× what cost_analysis reports."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, "src")
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.core import hloscan

        mesh = jax.make_mesh((8,), ("m",))
        sh = NamedSharding(mesh, P(None, "m"))
        wsh = NamedSharding(mesh, P(None, None, "m"))

        def f(x, w):
            def body(c, wi):
                return c @ wi, None
            y, _ = jax.lax.scan(body, x, w)
            return y

        x = jax.ShapeDtypeStruct((512, 512), jnp.float32)
        w = jax.ShapeDtypeStruct((10, 512, 512), jnp.float32)
        comp = jax.jit(f, in_shardings=(sh, wsh),
                       out_shardings=sh).lower(x, w).compile()
        res = hloscan.analyze_hlo(comp.as_text())
        expect = 2 * 10 * 512**3 / 8
        assert abs(res["flops"] - expect) / expect < 0.01, res["flops"]
        assert res.get("coll_all-gather", 0) > 0
        print("ANALYZER_OK", res["flops"])
    """)
    out = subprocess.run([sys.executable, "-c", prog], cwd=".",
                         capture_output=True, text=True, timeout=300)
    assert "ANALYZER_OK" in out.stdout, out.stdout + out.stderr


def test_collective_factors():
    text = """
ENTRY %main (p: f32[64]) -> f32[64] {
  %p = f32[64]{0} parameter(0)
  %ar = f32[64]{0} all-reduce(%p), to_apply=%add
  ROOT %ag = f32[64]{0} all-gather(%ar), dimensions={0}
}
"""
    got = hloscan.collective_bytes(text)
    assert got["all-reduce"] == 2 * 256      # 2× factor
    assert got["all-gather"] == 256
    assert got["total"] == 3 * 256
