"""Decode == prefill consistency across model families (KV cache, SSM
state, cross-attention, VLM prefix)."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import smoke_config
from repro.models import build_model

FAMILIES = ["llama3.2-3b", "gemma2-2b", "granite-20b", "mamba2-1.3b",
            "jamba-1.5-large-398b", "qwen3-moe-30b-a3b", "whisper-medium",
            "pixtral-12b"]


@pytest.mark.parametrize("arch", FAMILIES)
def test_decode_matches_prefill(arch):
    cfg = smoke_config(arch).with_overrides(dtype="float32")
    if cfg.moe is not None:  # avoid capacity-drop divergence (see test_moe)
        cfg = cfg.with_overrides(moe=dataclasses.replace(
            cfg.moe, capacity_factor=8.0))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    B, S = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks}
    n_front = 0
    if cfg.enc_dec:
        batch["frames"] = 0.1 * jax.random.normal(
            jax.random.PRNGKey(4), (B, cfg.frontend_len, cfg.d_model),
            cfg.jnp_dtype)
    if cfg.frontend == "vision":
        batch["patches"] = 0.1 * jax.random.normal(
            jax.random.PRNGKey(5), (B, cfg.frontend_len, cfg.d_model),
            cfg.jnp_dtype)
        n_front = cfg.frontend_len

    logits_full, _ = jax.jit(model.prefill)(params, batch)

    short = dict(batch)
    short["tokens"] = toks[:, :S - 1]
    _, cache = jax.jit(model.prefill)(params, short)
    # grow attention caches by one slot for the decode write
    cache = jax.tree.map(
        lambda x: jnp.pad(x, ((0, 0), (0, 0), (0, 1), (0, 0), (0, 0)))
        if x.ndim == 5 and x.shape[2] == S - 1 + n_front else x, cache)
    pos = S - 1 + n_front
    logits_dec, _ = jax.jit(model.decode_step)(
        params, cache, toks[:, S - 1:S], jnp.int32(pos))

    err = float(jnp.max(jnp.abs(logits_full - logits_dec)))
    assert err < 2e-3, f"{arch}: decode/prefill mismatch {err}"


def test_multi_step_decode_consistency():
    """Decoding 3 tokens step-by-step == prefill over the longer prompt."""
    cfg = smoke_config("llama3.2-3b").with_overrides(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 1, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    _, cache = jax.jit(model.prefill)(params, {"tokens": toks[:, :S - 3]})
    cache = jax.tree.map(
        lambda x: jnp.pad(x, ((0, 0), (0, 0), (0, 3), (0, 0), (0, 0)))
        if x.ndim == 5 else x, cache)
    decode = jax.jit(model.decode_step)
    for i in range(3):
        logits, cache = decode(params, cache, toks[:, S - 3 + i:S - 2 + i],
                               jnp.int32(S - 3 + i))
    logits_full, _ = jax.jit(model.prefill)(params, {"tokens": toks})
    err = float(jnp.max(jnp.abs(logits_full - logits)))
    assert err < 2e-3, err
