"""``repro.ops`` telemetry: the JSONL tracker's never-block contract
(bounded queue, drop counting, flush-on-close), torn-line tolerance,
and the periodic stats sampler."""

import json
import threading
import time

import pytest

from repro.ops import (JsonlTracker, NullTracker, StatsSampler, Tracker,
                       read_events, read_log)


def test_events_written_with_t_and_event(tmp_path):
    path = tmp_path / "m.jsonl"
    tr = JsonlTracker(path)
    tr.log_event("alpha", plan_id="p1")
    tr.log_metrics("gateway", {"served": 3})
    tr.close()
    events = read_events(path)
    assert [e["event"] for e in events] == ["alpha", "stats",
                                            "tracker_closed"]
    assert all("t" in e for e in events)
    assert events[0]["plan_id"] == "p1"
    assert events[1]["source"] == "gateway"
    assert events[1]["metrics"] == {"served": 3}


def test_close_is_idempotent_and_seals_totals(tmp_path):
    tr = JsonlTracker(tmp_path / "m.jsonl")
    for i in range(10):
        tr.log_event("e", i=i)
    tr.close()
    tr.close()                         # second close is a no-op
    events = read_events(tr.path)
    closed = events[-1]
    assert closed["event"] == "tracker_closed"
    assert closed["recorded"] == 10 and closed["dropped"] == 0
    assert len(events) == 11


def test_bounded_queue_drops_instead_of_blocking(tmp_path):
    """With the writer wedged, overflow must drop-and-count — record()
    never waits on the disk."""
    tr = JsonlTracker(tmp_path / "m.jsonl", max_queue=8,
                      flush_interval_s=30)
    gate = threading.Event()
    # wedge the writer thread inside a write
    tr._write = lambda entry, _w=tr._write: (gate.wait(5), _w(entry))[1]
    t0 = time.monotonic()
    for i in range(100):
        tr.log_event("burst", i=i)
    assert time.monotonic() - t0 < 2.0      # never blocked on the queue
    assert tr.dropped > 0
    assert tr.recorded + tr.dropped == 100
    gate.set()
    tr.close()
    events = read_events(tr.path)
    assert events[-1]["dropped"] == tr.dropped


def test_record_after_close_counts_dropped(tmp_path):
    tr = JsonlTracker(tmp_path / "m.jsonl")
    tr.log_event("before")
    tr.close()
    tr.log_event("after")              # silently dropped, counted
    assert tr.dropped == 1
    assert [e["event"] for e in read_events(tr.path)] \
        == ["before", "tracker_closed"]


def test_read_events_skips_torn_trailing_line(tmp_path):
    path = tmp_path / "m.jsonl"
    tr = JsonlTracker(path)
    tr.log_event("whole")
    tr.close()
    with open(path, "a", encoding="utf-8") as fh:
        fh.write('{"event": "torn-by-cra')   # crash mid-write
    events = read_events(path)
    assert [e["event"] for e in events] == ["whole", "tracker_closed"]


def test_unserializable_fields_fall_back_to_repr(tmp_path):
    tr = JsonlTracker(tmp_path / "m.jsonl")
    tr.log_event("odd", payload=object())
    tr.close()
    (entry,) = [e for e in read_events(tr.path) if e["event"] == "odd"]
    assert "object at 0x" in entry["payload"]


def test_tracker_context_manager(tmp_path):
    with JsonlTracker(tmp_path / "m.jsonl") as tr:
        tr.log_event("inside")
    assert [e["event"] for e in read_events(tr.path)] \
        == ["inside", "tracker_closed"]


def test_null_tracker_accepts_everything():
    tr = NullTracker()
    tr.log_event("x", a=1)
    tr.log_metrics("src", {"b": 2})
    tr.close()
    assert isinstance(tr, Tracker)


# ---------------------------------------------------------------------------
# read_log: the seal's loss accounting, surfaced (regression)
# ---------------------------------------------------------------------------

def test_read_log_surfaces_seal_drop_count(tmp_path):
    """Regression: ``read_events`` returned the events but swallowed the
    seal's loss accounting — recovery harnesses could not bound
    telemetry loss without re-parsing the seal by hand.  ``read_log``
    exposes recorded/dropped/write_errors from the seal record."""
    tr = JsonlTracker(tmp_path / "m.jsonl", max_queue=8,
                      flush_interval_s=30)
    gate = threading.Event()
    tr._write = lambda entry, _w=tr._write: (gate.wait(5), _w(entry))[1]
    for i in range(100):
        tr.log_event("burst", i=i)
    gate.set()
    tr.close()
    log = read_log(tr.path)
    assert log.sealed
    assert log.dropped == tr.dropped > 0
    assert log.recorded == tr.recorded
    assert log.write_errors == 0
    assert log.recorded + log.dropped == 100
    assert len(log.events) == log.recorded + 1      # + the seal itself
    # read_events stays the thin view over the same parse
    assert list(log.events) == read_events(tr.path)


def test_read_log_unsealed_and_torn_lines(tmp_path):
    # a tracker that died mid-flight left no seal: no loss bound exists
    path = tmp_path / "died.jsonl"
    path.write_text('{"event": "a", "t": 1.0}\n'
                    '{"event": "b", "t": 2.0}\n'
                    '{"event": "torn-by-cra')       # crash mid-write
    log = read_log(path)
    assert not log.sealed
    assert log.recorded is None and log.dropped is None
    assert log.torn_lines == 1
    assert [e["event"] for e in log.events] == ["a", "b"]
    # a torn append AFTER a clean close does not unseal the file — the
    # seal record is intact and its totals still hold
    tr = JsonlTracker(tmp_path / "closed.jsonl")
    tr.log_event("whole")
    tr.close()
    with open(tr.path, "a", encoding="utf-8") as fh:
        fh.write('{"event": "torn-by-cra')
    log = read_log(tr.path)
    assert log.sealed and log.recorded == 1 and log.torn_lines == 1


def test_io_fault_counts_write_errors_never_raises(tmp_path):
    """The ``io_fault=`` seam (``repro.chaos``'s tracker_disk_full):
    failed disk writes are counted, never raised to the caller, and the
    seal reports them so recovery tests can bound telemetry loss."""
    def io_fault(entry):
        if entry.get("event") == "doomed":
            raise OSError("disk full (injected)")

    tr = JsonlTracker(tmp_path / "m.jsonl", io_fault=io_fault)
    tr.log_event("ok-1")
    tr.log_event("doomed")
    tr.log_event("ok-2")
    tr.close()
    assert tr.write_errors == 1
    log = read_log(tr.path)
    assert [e["event"] for e in log.events] \
        == ["ok-1", "ok-2", "tracker_closed"]
    assert log.sealed and log.write_errors == 1
    # the seal's books balance: every enqueued entry is either on disk
    # or counted as a failed write
    assert log.recorded == 3 and log.dropped == 0
    assert len(log.events) - 1 == log.recorded - log.write_errors


# ---------------------------------------------------------------------------
# StatsSampler
# ---------------------------------------------------------------------------

def test_sampler_samples_periodically_and_on_close(tmp_path):
    calls = []

    def source():
        calls.append(1)
        return {"n": len(calls)}

    tr = JsonlTracker(tmp_path / "m.jsonl")
    sampler = StatsSampler(tr, {"fake": source}, interval_s=0.02)
    deadline = time.monotonic() + 5
    while sampler.samples < 3 and time.monotonic() < deadline:
        time.sleep(0.01)
    sampler.close()                    # + one final sample
    sampler.close()                    # idempotent
    tr.close()
    stats = [e for e in read_events(tr.path) if e["event"] == "stats"]
    assert len(stats) == len(calls) >= 4
    assert stats[-1]["metrics"]["n"] == len(calls)
    assert all(e["source"] == "fake" for e in stats)


def test_sampler_survives_raising_source(tmp_path):
    tr = JsonlTracker(tmp_path / "m.jsonl")

    def bad():
        raise RuntimeError("stats exploded")

    sampler = StatsSampler(tr, {"bad": bad, "good": lambda: {"ok": 1}},
                           interval_s=0.01)
    deadline = time.monotonic() + 5
    while sampler.samples < 2 and time.monotonic() < deadline:
        time.sleep(0.01)
    sampler.close()
    tr.close()
    events = read_events(tr.path)
    errors = [e for e in events if e["event"] == "sample_error"]
    good = [e for e in events if e["event"] == "stats"]
    assert errors and "stats exploded" in errors[0]["error"]
    assert good and all(e["source"] == "good" for e in good)
