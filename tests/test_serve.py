"""Serving engine: greedy decode equals a hand-rolled reference loop;
continuous batching completes mixed workloads."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.models import build_model
from repro.serve import Engine, Request, ServeConfig


def _model():
    cfg = smoke_config("llama3.2-3b").with_overrides(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _reference_greedy(model, params, prompt, n_new):
    """prefill + argmax loop without the engine."""
    toks = list(prompt)
    out = []
    for _ in range(n_new):
        logits, _ = jax.jit(model.prefill)(
            params, {"tokens": jnp.asarray([toks], jnp.int32)})
        t = int(jnp.argmax(logits[0]))
        out.append(t)
        toks.append(t)
    return out


def test_engine_matches_reference_greedy():
    cfg, model, params = _model()
    prompt = [5, 9, 2, 11, 3, 7, 1, 8]
    n_new = 6
    ref = _reference_greedy(model, params, prompt, n_new)
    eng = Engine(model, params, ServeConfig(max_batch=2, max_len=64,
                                            max_new_tokens=n_new))
    req = Request(prompt=prompt)
    eng.run([req])
    assert req.out_tokens[:n_new] == ref[:n_new], \
        (req.out_tokens, ref)


def test_batch_of_requests_completes():
    cfg, model, params = _model()
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=list(rng.integers(1, cfg.vocab_size, 8)),
                    request_id=i) for i in range(5)]
    eng = Engine(model, params, ServeConfig(max_batch=2, max_len=40,
                                            max_new_tokens=5))
    eng.run(reqs)
    for r in reqs:
        assert r.done and len(r.out_tokens) == 5


def test_batched_equals_solo():
    """Same request decoded alone and inside a batch must match (slot
    isolation)."""
    cfg, model, params = _model()
    p1 = [4, 8, 15, 16, 23, 42, 7, 9]
    p2 = [1, 2, 3, 4, 5, 6, 7, 8]
    solo = Request(prompt=list(p1))
    Engine(model, params, ServeConfig(max_batch=1, max_len=48,
                                      max_new_tokens=4)).run([solo])
    r1, r2 = Request(prompt=list(p1)), Request(prompt=list(p2))
    Engine(model, params, ServeConfig(max_batch=2, max_len=48,
                                      max_new_tokens=4)).run([r1, r2])
    assert solo.out_tokens == r1.out_tokens
