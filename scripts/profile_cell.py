"""Dry-run profiler: top FLOPs / HBM-traffic / collective contributors of a
saved cell HLO (results/<tag>__<cell>.hlo.gz) — the §Perf 'profile'."""

import gzip
import re
import sys

sys.path.insert(0, "src")

from repro.core import hloscan  # noqa: E402


def profile(path, topn=12):
    text = gzip.open(path, "rt").read()
    mod = hloscan.HloModule(text)
    flops, traffic, colls = {}, {}, {}

    def walk(comp, mult):
        for name, type_str, op, rest in mod.computations.get(comp, []):
            meta = re.search(r'op_name="([^"]+)"', rest)
            tag = meta.group(1).split("/")[-2:] if meta else [op]
            tag = "/".join(tag)[:70]
            if op in ("dot", "convolution"):
                flops[tag] = flops.get(tag, 0) + \
                    mod._dot_flops(type_str, rest) * mult
            if op in hloscan._COLLECTIVES:
                b = hloscan._shape_bytes(type_str) * \
                    hloscan._COLLECTIVE_FACTOR[op]
                colls[f"{op}:{tag}"] = colls.get(f"{op}:{tag}", 0) + b * mult
            if op in hloscan._MACRO_TRAFFIC_OPS:
                t = mod._macro_traffic(name, type_str, op, rest) * mult
                key = re.sub(r"[.\d]+$", "", name)
                traffic[key] = traffic.get(key, 0) + t
            if op == "while":
                bm = re.search(r"body=%?([\w.\-]+)", rest)
                tm = hloscan._TRIP_CFG.search(rest)
                trip = int(tm.group(1)) if tm else 1
                if bm:
                    walk(bm.group(1), mult * trip)
            elif op == "fusion":
                cm = re.search(r"calls=%?([\w.\-]+)", rest)
                if cm:
                    walk(cm.group(1), mult)

    walk(mod.entry, 1.0)
    for title, d, unit in (("FLOPS", flops, 1e12),
                           ("HBM TRAFFIC", traffic, 2**30),
                           ("COLLECTIVES", colls, 2**30)):
        total = sum(d.values())
        print(f"\n== {title}: total {total/unit:.2f} "
              f"{'T' if unit == 1e12 else 'GiB'} ==")
        for k, v in sorted(d.items(), key=lambda kv: -kv[1])[:topn]:
            print(f"  {v/unit:10.2f} ({v/total*100:5.1f}%)  {k}")


if __name__ == "__main__":
    profile(sys.argv[1], int(sys.argv[2]) if len(sys.argv) > 2 else 12)
