#!/usr/bin/env python
"""Acceptance gate for ``BENCH_async_serve.json`` (async gateway vs
tick loop).

The adaptive-admission gateway must dominate the seed's tick loop on
throughput and win decisively on overload latency:

  * ``speedup_images_per_sec >= 1.0`` at **every** occupancy — the
    bounded, adaptive front door may never cost images/sec versus
    blind unbounded queueing;
  * ``p99_ratio_async_vs_tick <= 0.7`` at occupancy 2.0 — the wait
    budget must actually cap tail latency under overload, not just
    relabel the queue.

Run after regenerating the bench (CI sweep job does both):

    python benchmarks/async_serve_bench.py
    python scripts/check_async_bench.py [BENCH_async_serve.json]

Exits non-zero with a per-occupancy verdict when the artifact misses
either bar.
"""

from __future__ import annotations

import json
import sys
from pathlib import Path

MIN_SPEEDUP = 1.0
MAX_P99_RATIO_AT_2X = 0.7
P99_GATED_OCCUPANCY = 2.0


def check(path: str | Path) -> int:
    payload = json.loads(Path(path).read_text())
    rows = payload.get("occupancy_results", [])
    if not rows:
        print(f"FAIL {path}: no occupancy_results")
        return 1
    failures = 0
    for row in rows:
        occ = row["occupancy"]
        speedup = row["speedup_images_per_sec"]
        p99_ratio = row["p99_ratio_async_vs_tick"]
        problems = []
        if speedup < MIN_SPEEDUP:
            problems.append(
                f"speedup {speedup:.3f} < {MIN_SPEEDUP}")
        if occ == P99_GATED_OCCUPANCY and \
                p99_ratio > MAX_P99_RATIO_AT_2X:
            problems.append(
                f"p99 ratio {p99_ratio:.3f} > {MAX_P99_RATIO_AT_2X}")
        verdict = "FAIL" if problems else "ok"
        failures += bool(problems)
        print(f"{verdict}  occ={occ:g}  speedup={speedup:.3f}x  "
              f"p99_ratio={p99_ratio:.3f}"
              + (f"  [{'; '.join(problems)}]" if problems else ""))
    if failures:
        print(f"FAIL: {failures}/{len(rows)} occupancies miss "
              f"acceptance")
        return 1
    print("acceptance: async >= tick images/sec at every occupancy, "
          "p99 <= 0.7x at 2x overload")
    return 0


if __name__ == "__main__":
    sys.exit(check(sys.argv[1] if len(sys.argv) > 1
                   else "BENCH_async_serve.json"))
