#!/usr/bin/env python
"""Acceptance gate for ``BENCH_recovery.json`` (kill-mid-trace fleet
recovery, live and simulated).

The recovery contract the chaos layer pins:

  * **zero lost** — through the kill, the simulated fleet completes
    every request (``completed == requests``, ``lost == 0``) and the
    live fleet accounts for every admitted request
    (``completed + refused == requests``), bit-exactly;
  * the kill actually **re-routed** work (sim ``kill_rerouted > 0``,
    live ``rerouted > 0``) — a kill that evicted nothing proves
    nothing;
  * the **warm respawn compiles nothing** — the replacement gateway
    rebuilt from the shared ``StoreRoot`` reports ``compiles == 0``
    with ``disk_hits > 0`` (every executable deserialized from what
    the dead predecessor had stored), and the health probe re-admitted
    the worker;
  * the simulated respawn demonstrably **returns the worker to
    rotation** (it serves strictly more than in the no-respawn run).

Run after regenerating the bench (CI chaos job does both):

    python benchmarks/recovery_bench.py
    python scripts/check_recovery_bench.py [BENCH_recovery.json]

Exits non-zero with a verdict per gate when the artifact misses a bar.
"""

from __future__ import annotations

import json
import sys
from pathlib import Path


def check(path: str | Path) -> int:
    payload = json.loads(Path(path).read_text())
    sim, live = payload.get("sim"), payload.get("live")
    if not sim or not live:
        print(f"FAIL {path}: missing sim/live results")
        return 1
    failures = 0
    killed = sim["runs"]["kill_respawn"]
    dead = sim["runs"]["kill_only"]
    victim = sim["kill_worker"]

    ok = killed["lost"] == 0 and killed["completed"] == sim["requests"]
    failures += not ok
    print(f"{'ok  ' if ok else 'FAIL'} sim zero lost: completed "
          f"{killed['completed']}/{sim['requests']}, lost "
          f"{killed['lost']} (must complete everything, lose nothing)")

    ok = killed["kill_rerouted"] > 0
    failures += not ok
    print(f"{'ok  ' if ok else 'FAIL'} sim kill re-routed "
          f"{killed['kill_rerouted']} requests (must be > 0: the kill "
          f"evicted a real queue/in-flight batch)")

    served = killed["per_worker"][victim]["served"]
    served_dead = dead["per_worker"][victim]["served"]
    ok = served > served_dead
    failures += not ok
    print(f"{'ok  ' if ok else 'FAIL'} sim respawn restored service: "
          f"{victim} served {served} with respawn vs {served_dead} "
          f"without")

    ok = live["completed"] + live["refused"] == live["requests"] \
        and live["bit_exact"]
    failures += not ok
    print(f"{'ok  ' if ok else 'FAIL'} live accounting: "
          f"{live['completed']} completed + {live['refused']} refused "
          f"== {live['requests']} admitted, bit_exact="
          f"{live['bit_exact']}")

    ok = live["rerouted"] > 0 and live["kills"] == 1 \
        and live["respawns"] == 1 and live["worker_readmitted"]
    failures += not ok
    print(f"{'ok  ' if ok else 'FAIL'} live kill→respawn path: "
          f"rerouted {live['rerouted']}, kills {live['kills']}, "
          f"respawns {live['respawns']}, readmitted "
          f"{live['worker_readmitted']}")

    ok = live["respawn_compiles"] == 0 and live["respawn_disk_hits"] > 0
    failures += not ok
    print(f"{'ok  ' if ok else 'FAIL'} warm respawn compiles "
          f"{live['respawn_compiles']} (must be 0), disk_hits "
          f"{live['respawn_disk_hits']} (must be > 0: restart-from-"
          f"store deserializes everything)")

    if failures:
        print(f"FAIL {path}: {failures} gate(s) missed")
        return 1
    print(f"ok   {path}: kill→respawn loses nothing; warm respawn "
          f"served first request in "
          f"{live['respawn_first_served_s'] * 1e3:.1f} ms with zero "
          f"recompiles")
    return 0


if __name__ == "__main__":
    sys.exit(check(sys.argv[1] if len(sys.argv) > 1
                   else "BENCH_recovery.json"))
