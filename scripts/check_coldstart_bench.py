#!/usr/bin/env python
"""Acceptance gate for ``BENCH_coldstart.json`` (persistent executable
cache, cold vs warm restart).

A warm restart over a populated cache directory must actually skip the
compiler, not merely shave it:

  * ``warm_compiles == 0`` — every executable deserializes from disk;
    a single live compile means a key or fingerprint regressed;
  * ``speedup >= 3.0`` — cold-start-to-first-served must be ≥ 3×
    faster warm than cold (CPU XLA compiles of the quickstart ladder
    take seconds; deserialization takes tens of milliseconds);
  * the warm run's ``disk_hits`` covers what the cold run compiled —
    a warm start that silently recompiled *and* re-stored would show
    hits < stores.

Run after regenerating the bench (CI sweep job does both):

    python benchmarks/coldstart_bench.py
    python scripts/check_coldstart_bench.py [BENCH_coldstart.json]

Exits non-zero with a verdict per gate when the artifact misses a bar.
"""

from __future__ import annotations

import json
import sys
from pathlib import Path

MIN_SPEEDUP = 3.0


def check(path: str | Path) -> int:
    payload = json.loads(Path(path).read_text())
    cold, warm = payload.get("cold"), payload.get("warm")
    if not cold or not warm:
        print(f"FAIL {path}: missing cold/warm results")
        return 1
    failures = 0

    speedup = payload["speedup"]
    ok = speedup >= MIN_SPEEDUP
    failures += not ok
    print(f"{'ok  ' if ok else 'FAIL'} speedup "
          f"{speedup:.2f}x (cold {cold['to_first_served_s']:.3f}s → "
          f"warm {warm['to_first_served_s']:.3f}s; need ≥ "
          f"{MIN_SPEEDUP:g}x)")

    ok = warm["compiles"] == 0
    failures += not ok
    print(f"{'ok  ' if ok else 'FAIL'} warm compiles "
          f"{warm['compiles']} (must be 0: every executable "
          f"deserialized)")

    ok = warm["disk_hits"] >= cold["disk_stores"] > 0
    failures += not ok
    print(f"{'ok  ' if ok else 'FAIL'} warm disk_hits "
          f"{warm['disk_hits']} covers cold disk_stores "
          f"{cold['disk_stores']}")

    if failures:
        print(f"FAIL {path}: {failures} gate(s) missed")
        return 1
    print(f"ok   {path}: warm restart serves from disk "
          f"({speedup:.1f}x faster to first served)")
    return 0


if __name__ == "__main__":
    sys.exit(check(sys.argv[1] if len(sys.argv) > 1
                   else "BENCH_coldstart.json"))
