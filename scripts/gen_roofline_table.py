"""Generate the EXPERIMENTS.md §Roofline markdown table from results/."""

import glob
import json
import sys

sys.path.insert(0, "src")

from repro.core.roofline import roofline_terms  # noqa: E402


def fmt(v):
    if v == 0:
        return "0"
    if v < 0.001 or v >= 10000:
        return f"{v:.2e}"
    return f"{v:.3f}"


def main(tag="baseline", mesh="single"):
    rows = []
    for f in sorted(glob.glob(f"results/{tag}__*__{mesh}.json")):
        r = json.load(open(f))
        if r["status"] == "skipped":
            rows.append((r["arch"], r["shape"], None, r["reason"]))
            continue
        if r["status"] != "ok":
            rows.append((r["arch"], r["shape"], None,
                         "ERROR " + r.get("error", "?")[:60]))
            continue
        t = roofline_terms(r)
        rows.append((r["arch"], r["shape"], t, r))
    print("| arch | shape | compute s | memory s | coll s | dominant | "
          "MODEL_FLOPS | useful ratio | roofline frac | note |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for arch, shape, t, extra in rows:
        if t is None:
            print(f"| {arch} | {shape} | — | — | — | — | — | — | — | "
                  f"skipped: {extra[:70]} |")
            continue
        r = extra
        mem_gib = r["memory"].get("argument_size_in_bytes", 0) / 2**30
        tmp_gib = r["memory"].get("temp_size_in_bytes", 0) / 2**30
        note = (f"args {mem_gib:.1f}+tmp {tmp_gib:.1f} GiB/dev, "
                f"{r['mode']}")
        print(f"| {arch} | {shape} | {fmt(t['compute_s'])} | "
              f"{fmt(t['memory_s'])} | {fmt(t['collective_s'])} | "
              f"{t['dominant'].removesuffix('_s')} | "
              f"{t['model_flops']:.2e} | {t['useful_flops_ratio']:.3f} | "
              f"{t['roofline_fraction']:.4f} | {note} |")


if __name__ == "__main__":
    main(*sys.argv[1:])
