"""Execute every fenced ``python`` snippet in ``docs/*.md`` so the
documentation can't rot (CI job ``docs``).

    PYTHONPATH=src python scripts/check_docs.py [--only docs/serve.md] [-v]

Rules:

* Fences whose info string is exactly ``python`` are executed, in file
  order, sharing one namespace per document — a doc reads top-to-bottom
  as one runnable session (later snippets may use earlier variables).
* Fences tagged ``python no-check`` are skipped (illustrative
  fragments; renderers still highlight them — the first word wins).
* All other fences (``bash``, plain, ...) are ignored.
* Each document runs with the repo root as cwd and a private temp
  directory exported as ``DOCS_TMP`` — snippets that write artifacts
  (plans, sweep caches) must target it rather than polluting the repo.

A snippet failure reports the doc, the snippet's line number, and the
traceback, and the script exits non-zero.
"""

from __future__ import annotations

import argparse
import os
import re
import sys
import tempfile
import time
import traceback
import types
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
FENCE = re.compile(r"^```(.*?)\s*$")


def extract_snippets(text: str):
    """Yield (info_string, start_line, source) per fenced code block."""
    lines = text.splitlines()
    i = 0
    while i < len(lines):
        m = FENCE.match(lines[i])
        if m and m.group(1):
            info = m.group(1)
            start = i + 2               # 1-based first source line
            body = []
            i += 1
            while i < len(lines) and not lines[i].startswith("```"):
                body.append(lines[i])
                i += 1
            yield info, start, "\n".join(body)
        i += 1


def run_doc(path: Path, verbose: bool = False) -> tuple[int, int, int]:
    """Execute a document's python snippets; returns (ran, skipped,
    failed)."""
    # a real registered module, not a bare dict: snippets that define
    # dataclasses (or anything else that looks itself up through
    # ``sys.modules[cls.__module__]``) then behave like normal files
    mod = types.ModuleType(f"docs_check_{path.stem}")
    sys.modules[mod.__name__] = mod
    ns = mod.__dict__
    ran = skipped = failed = 0
    raw = path.read_text()
    try:
        for info, line, src in extract_snippets(raw):
            words = info.split()        # "python", "python no-check", ...
            if not words or words[0] != "python":
                continue
            if "no-check" in words[1:]:
                skipped += 1
                continue
            t0 = time.time()
            try:
                code = compile(src, f"{path}:{line}", "exec")
                exec(code, ns)
                ran += 1
                if verbose:
                    print(f"    ok   {path.name}:{line} "
                          f"({time.time() - t0:.1f}s)")
            except Exception:
                failed += 1
                print(f"FAILED {path}:{line}")
                traceback.print_exc()
                break                   # later snippets depend on this one
    finally:
        sys.modules.pop(mod.__name__, None)
    return ran, skipped, failed


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", action="append", default=None,
                    help="check only this doc (repeatable)")
    ap.add_argument("-v", "--verbose", action="store_true")
    args = ap.parse_args()

    sys.path.insert(0, str(REPO / "src"))
    os.chdir(REPO)
    docs = [Path(p).resolve() for p in args.only] if args.only \
        else sorted((REPO / "docs").glob("*.md"))
    if not docs:
        print("no docs found", file=sys.stderr)
        return 2

    total_failed = 0
    for doc in docs:
        with tempfile.TemporaryDirectory(prefix="docs_check_") as tmp:
            os.environ["DOCS_TMP"] = tmp
            t0 = time.time()
            ran, skipped, failed = run_doc(doc, args.verbose)
            total_failed += failed
            status = "FAIL" if failed else "ok"
            print(f"[docs-check] {doc.relative_to(REPO)}: {ran} ran, "
                  f"{skipped} skipped ({time.time() - t0:.1f}s) {status}")
    return 1 if total_failed else 0


if __name__ == "__main__":
    sys.exit(main())
